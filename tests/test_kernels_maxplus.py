"""maxplus_scan kernel equivalence: Pallas (interpret) vs associative
scan vs sequential ref vs the numpy ``maximum.accumulate`` oracle, across
dtypes, lengths, resets, and init values."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import enable_x64

from repro.kernels.maxplus_scan import (maxplus_depart,
                                        maxplus_depart_kernel,
                                        maxplus_depart_ref)


def numpy_oracle(arrive, svc):
    """The expression the fast engine historically inlined."""
    s = np.cumsum(svc, axis=-1)
    return s + np.maximum.accumulate(arrive - (s - svc), axis=-1)


def sequential_oracle(arrive, svc, reset=None, init=None):
    out = np.empty_like(arrive)
    flat_a = arrive.reshape(-1, arrive.shape[-1])
    flat_s = svc.reshape(-1, arrive.shape[-1])
    flat_r = (None if reset is None
              else reset.reshape(-1, arrive.shape[-1]))
    for r in range(flat_a.shape[0]):
        d = -np.inf if init is None else float(np.asarray(init).reshape(-1)[
            r % np.asarray(init).size])
        for i in range(arrive.shape[-1]):
            if flat_r is not None and flat_r[r, i]:
                d = -np.inf
            d = max(flat_a[r, i], d) + flat_s[r, i]
            out.reshape(-1, arrive.shape[-1])[r, i] = d
    return out


def make(shape, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    arrive = np.sort(rng.random(shape), axis=-1).astype(dtype) * 10
    svc = (rng.random(shape) * 0.3).astype(dtype)
    return arrive, svc


@pytest.mark.parametrize("L", [1, 7, 128, 1000])
def test_numpy_backend_is_bit_exact_vs_inline_oracle(L):
    a, s = make((3, L))
    got = maxplus_depart(a, s, backend="numpy")
    assert np.array_equal(got, numpy_oracle(a, s))


@pytest.mark.parametrize("backend", ["assoc", "ref", "pallas"])
@pytest.mark.parametrize("L,chunk", [(8, 8), (96, 16), (250, 64)])
def test_jax_backends_match_numpy_oracle_f64(backend, L, chunk):
    a, s = make((4, L), seed=L)
    with enable_x64():
        got = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                        backend=backend, chunk=chunk,
                                        interpret=True))
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-12,
                               atol=1e-12)


@pytest.mark.parametrize("backend", ["assoc", "pallas"])
def test_float32_tolerance(backend):
    a, s = make((2, 64), seed=5, dtype=np.float32)
    got = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                    backend=backend, chunk=16,
                                    interpret=True))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-5,
                               atol=1e-5)


def test_auto_backend_dispatch():
    a, s = make((2, 32))
    assert isinstance(maxplus_depart(a, s), np.ndarray)
    out = maxplus_depart(jnp.asarray(a), jnp.asarray(s))
    assert isinstance(out, jax.Array)


@pytest.mark.parametrize("backend", ["numpy", "assoc", "ref"])
def test_segment_resets(backend):
    a, s = make((3, 40), seed=9)
    reset = np.zeros((3, 40), bool)
    reset[:, 13] = True
    reset[1, 0] = True
    reset[2, 39] = True
    want = sequential_oracle(a, s, reset=reset)
    with enable_x64():
        got = np.asarray(maxplus_depart(
            jnp.asarray(a) if backend != "numpy" else a,
            jnp.asarray(s) if backend != "numpy" else s,
            reset=jnp.asarray(reset) if backend != "numpy" else reset,
            backend=backend))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("backend", ["numpy", "assoc", "ref"])
def test_init_busy_leader(backend):
    a, s = make((4, 25), seed=3)
    init = np.array([0.0, 5.0, 20.0, 2.5])
    want = sequential_oracle(a, s, init=init)
    with enable_x64():
        got = np.asarray(maxplus_depart(
            jnp.asarray(a) if backend != "numpy" else a,
            jnp.asarray(s) if backend != "numpy" else s,
            init=jnp.asarray(init) if backend != "numpy" else init,
            backend=backend))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_pallas_rows_are_independent():
    """The VMEM carry must reset per row: permuting rows permutes
    departures."""
    a, s = make((5, 64), seed=11)
    with enable_x64():
        out = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                        backend="pallas", chunk=16,
                                        interpret=True))
        perm = np.array([3, 1, 4, 0, 2])
        out_p = np.asarray(maxplus_depart(jnp.asarray(a[perm]),
                                          jnp.asarray(s[perm]),
                                          backend="pallas", chunk=16,
                                          interpret=True))
    np.testing.assert_allclose(out_p, out[perm], rtol=1e-12)


def test_pallas_pad_to_chunk():
    """Non-multiple lengths are padded and sliced back."""
    a, s = make((2, 37), seed=13)
    with enable_x64():
        got = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                        backend="pallas", chunk=16,
                                        interpret=True))
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-12)


def test_kernel_direct_multiple_of_chunk():
    a, s = make((3, 32), seed=17, dtype=np.float32)
    got = np.asarray(maxplus_depart_kernel(jnp.asarray(a), jnp.asarray(s),
                                           chunk=8, interpret=True))
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("block_rows", [2, 4, 8])
@pytest.mark.parametrize("R,L", [(1, 64), (5, 96), (16, 37)])
def test_pallas_batched_rows_matches_oracle(block_rows, R, L):
    """The ``block_rows`` grid axis tiles rows; results must not depend
    on the tile size, including when R is not a multiple of it."""
    a, s = make((R, L), seed=R * 100 + L)
    with enable_x64():
        got = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                        backend="pallas", chunk=16,
                                        block_rows=block_rows,
                                        interpret=True))
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-12,
                               atol=1e-12)


def test_pallas_block_rows_bitwise_vs_block_rows_one():
    """Row tiling is pure batching: each row's scan is independent, so
    block_rows must be bit-invisible, not just within tolerance."""
    a, s = make((7, 48), seed=41)
    with enable_x64():
        one = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                        backend="pallas", chunk=16,
                                        block_rows=1, interpret=True))
        many = np.asarray(maxplus_depart(jnp.asarray(a), jnp.asarray(s),
                                         backend="pallas", chunk=16,
                                         block_rows=4, interpret=True))
    assert np.array_equal(one, many)


def test_monotone_departures_and_fifo_invariant():
    """Departures are nondecreasing in op order and each op departs no
    earlier than its own arrival + service."""
    a, s = make((1, 200), seed=23)
    d = maxplus_depart(a, s)
    assert np.all(np.diff(d[0]) >= 0)
    assert np.all(d >= a + s - 1e-12)


def test_ref_rejects_nothing_on_1d():
    a, s = make((16,), seed=29)
    with enable_x64():
        got = np.asarray(maxplus_depart_ref(a, s))
    np.testing.assert_allclose(got, numpy_oracle(a, s), rtol=1e-12)
