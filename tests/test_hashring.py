"""Chord ring unit + property tests: lookup correctness, O(log m) hops,
consistent-hashing remap bound, virtual-node balance."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hashring import ChordRing, stable_hash, RING_SIZE


def make_ring(m: int, vnodes: int = 1) -> ChordRing:
    r = ChordRing(virtual_nodes=vnodes)
    for i in range(m):
        r.add_node(f"gw{i}")
    return r


def brute_force_owner(ring: ChordRing, key: str) -> str:
    kh = stable_hash(key)
    best, best_dist = None, None
    for nid, vhs in ring.nodes.items():
        for vh in vhs:
            dist = (vh - kh) % RING_SIZE
            if best_dist is None or dist < best_dist:
                best, best_dist = nid, dist
    return best


def test_locate_matches_brute_force():
    ring = make_ring(16, vnodes=4)
    for i in range(500):
        key = f"key-{i}"
        assert ring.locate(key) == brute_force_owner(ring, key)


def test_route_reaches_owner_from_every_start():
    ring = make_ring(12)
    for i in range(50):
        key = f"k{i}"
        owner = ring.locate(key)
        for start in list(ring.nodes)[:4]:
            path = ring.route(start, key)
            assert path[-1] == owner
            assert path[0] == start


def test_route_hop_bound_logarithmic():
    """Chord promises O(log m) hops; check a generous c*log2(m)+c bound."""
    for m in (4, 16, 64, 128):
        ring = make_ring(m)
        bound = 2 * math.log2(m) + 4
        worst = 0
        for i in range(200):
            path = ring.route("gw0", f"key-{i}")
            worst = max(worst, len(path) - 1)
        assert worst <= bound, (m, worst, bound)


def test_finger_state_logarithmic():
    for m in (8, 32, 128):
        ring = make_ring(m)
        bound = 4 * math.log2(m) + 8
        assert ring.finger_table_size("gw0") <= bound


def test_consistent_hashing_remap_bound():
    """Adding one node to m moves ~K/(m+1) keys; assert <= 3x expectation."""
    keys = [f"key-{i}" for i in range(3000)]
    for m in (8, 16):
        before = make_ring(m, vnodes=8)
        after = make_ring(m, vnodes=8)
        after.add_node("gw-new")
        moved = before.moved_keys(keys, after)
        expected = len(keys) / (m + 1)
        assert moved <= 3 * expected, (m, moved, expected)
        # and removal moves nothing except the removed node's keys
        after.remove_node("gw-new")
        assert before.moved_keys(keys, after) == 0


def test_virtual_nodes_improve_balance():
    keys = [f"key-{i}" for i in range(5000)]
    flat = make_ring(10, vnodes=1).key_distribution(keys)
    virt = make_ring(10, vnodes=32).key_distribution(keys)

    def imbalance(d):
        mean = sum(d.values()) / len(d)
        return max(d.values()) / mean

    assert imbalance(virt) < imbalance(flat)
    assert imbalance(virt) < 1.6  # well balanced with 32 vnodes


def test_weighted_virtual_nodes():
    ring = ChordRing(virtual_nodes=16)
    ring.add_node("big", weight=3.0)
    ring.add_node("small", weight=1.0)
    dist = ring.key_distribution([f"k{i}" for i in range(4000)])
    assert dist["big"] > 2.0 * dist["small"]


def test_vnode_count_monotone_in_weight():
    """Regression: banker's rounding mapped halfway weights
    non-monotonically (1.5 -> 2 but 2.5 -> 2 with base_vnodes=1), so a
    strictly larger weight could own *fewer* ring arcs. Counts must be
    non-decreasing in the weight for every base vnode multiplier."""
    for base in (1, 2, 3, 8):
        ring = ChordRing(virtual_nodes=base)
        weights = [w / 4 for w in range(1, 41)]  # 0.25 .. 10.0 step 0.25
        counts = [ring._vnode_count(w) for w in weights]
        assert counts == sorted(counts), (base, counts)
        # half-up at the .5 boundaries, never half-to-even
        assert ring._vnode_count(1.5) == round(1.5 * base + 0.5 - 1e-12) \
            or ring._vnode_count(1.5) == int(1.5 * base + 0.5)
    with pytest.raises(ValueError):
        ChordRing()._vnode_count(0.0)


def test_reweight_node_equivalent_to_full_rebuild():
    """Incremental reweight (suffix add/remove of the vnode sequence)
    must land on exactly the ring a from-scratch build with the new
    weight produces — same vnode hashes, same owner for every key."""
    keys = [f"key-{i}" for i in range(1500)]
    for new_w in (0.25, 0.5, 1.0, 2.0, 3.5):
        inc = make_ring(8, vnodes=4)
        rebuilds_before = inc.finger_rebuilds
        added, removed = inc.reweight_node("gw3", new_w)
        assert inc.finger_rebuilds == rebuilds_before  # incremental only
        full = ChordRing(virtual_nodes=4)
        for i in range(8):
            full.add_node(f"gw{i}", weight=new_w if i == 3 else 1.0)
        assert sorted(inc.nodes["gw3"]) == sorted(full.nodes["gw3"])
        assert inc._vhashes == full._vhashes
        for k in keys:
            assert inc.locate(k) == full.locate(k), k
        # the delta is exactly the suffix the count change implies
        c_new = inc._vnode_count(new_w)
        assert len(added) == max(0, c_new - 4)
        assert len(removed) == max(0, 4 - c_new)


def test_reweight_noop_when_count_unchanged():
    ring = make_ring(6, vnodes=4)
    before = list(ring._vhashes)
    added, removed = ring.reweight_node("gw2", 1.05)  # same vnode count
    assert (added, removed) == ([], [])
    assert ring._vhashes == before
    assert ring.weights["gw2"] == 1.05  # weight still recorded


def test_weight_entries_never_leak():
    """Regression: remove/crash paths each deleted the weight entry ad
    hoc and one path forgot, so a node could depart leaving a stale
    weight that a later re-add silently resurrected. All teardown now
    routes through _drop_weight."""
    ring = make_ring(6, vnodes=4)
    ring.reweight_node("gw1", 3.0)
    ring.remove_node("gw1")
    assert "gw1" not in ring.weights
    ring.crash_node("gw2")
    assert "gw2" not in ring.weights
    assert set(ring.weights) == set(ring.nodes)
    # re-adding gets the default weight, not the leaked 3.0
    ring.add_node("gw1")
    assert ring.weights["gw1"] == 1.0
    assert len(ring.nodes["gw1"]) == ring._vnode_count(1.0)


def test_successor_group_rule():
    ring = make_ring(5)
    for nid in list(ring.nodes):
        succ = ring.successor_group(nid)
        assert succ != nid
        assert succ in ring.nodes


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=50, unique=True),
       st.text(min_size=1, max_size=20))
def test_property_locate_is_stable_and_total(node_ids, key):
    ring = ChordRing()
    for nid in node_ids:
        ring.add_node(f"n{nid}")
    owner1 = ring.locate(key)
    owner2 = ring.locate(key)
    assert owner1 == owner2
    assert owner1 in ring.nodes


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2**32 - 1))
def test_property_remove_then_add_is_identity(m, salt):
    ring = make_ring(m, vnodes=4)
    keys = [f"{salt}-{i}" for i in range(200)]
    before = {k: ring.locate(k) for k in keys}
    ring.remove_node("gw1")
    ring.add_node("gw1")
    after = {k: ring.locate(k) for k in keys}
    assert before == after
