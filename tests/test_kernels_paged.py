"""Paged-attention kernel vs oracle: page-table indirection, ragged
lengths, shared (deduplicated) global pages — EdgeKV semantics on TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.paged_attention import paged_attention


def make_case(key, B, H, K, hd, n_pages, page, P_max, max_len):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (K, n_pages, page, hd))
    vp = jax.random.normal(ks[2], (K, n_pages, page, hd))
    pt = jax.random.randint(ks[3], (B, P_max), 0, n_pages)
    lengths = jax.random.randint(ks[4], (B,), 1, max_len + 1)
    return q, kp, vp, pt, lengths


@pytest.mark.parametrize("B,H,K,hd,page,P_max", [
    (2, 4, 2, 32, 8, 4),
    (3, 8, 8, 16, 16, 3),   # MHA-ish
    (1, 8, 1, 64, 8, 6),    # MQA
])
def test_paged_matches_oracle(B, H, K, hd, page, P_max):
    q, kp, vp, pt, ln = make_case(jax.random.PRNGKey(0), B, H, K, hd,
                                  16, page, P_max, page * P_max)
    ref = paged_attention(q, kp, vp, pt, ln, use_pallas=False)
    got = paged_attention(q, kp, vp, pt, ln, use_pallas=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_shared_prefix_pages():
    """Two sequences sharing a global (deduplicated) prefix page must see
    identical attention over that page — the EdgeKV global-tier dedup."""
    B, H, K, hd, page = 2, 2, 2, 16, 8
    q0 = jax.random.normal(jax.random.PRNGKey(1), (1, H, hd))
    q = jnp.concatenate([q0, q0], axis=0)
    kp = jax.random.normal(jax.random.PRNGKey(2), (K, 4, page, hd))
    vp = jax.random.normal(jax.random.PRNGKey(3), (K, 4, page, hd))
    pt = jnp.array([[2, 0], [2, 1]])     # page 2 = shared global prefix
    ln = jnp.array([page, page])         # only the shared page is valid
    out = paged_attention(q, kp, vp, pt, ln, use_pallas=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6, atol=1e-6)


def test_paged_ragged_lengths_ignore_garbage():
    """Entries past `length` must not affect output, whatever the table
    points at."""
    B, H, K, hd, page, P_max = 1, 2, 2, 16, 8, 4
    q, kp, vp, pt, _ = make_case(jax.random.PRNGKey(4), B, H, K, hd, 8,
                                 page, P_max, page * P_max)
    ln = jnp.array([11])
    out1 = paged_attention(q, kp, vp, pt, ln, use_pallas=True,
                           interpret=True)
    # scramble the pages beyond ceil(11/8)=2
    pt2 = pt.at[0, 2:].set(7)
    out2 = paged_attention(q, kp, vp, pt2, ln, use_pallas=True,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([2, 4]), st.sampled_from([8, 16]))
def test_paged_property_random_shapes(B, K, page):
    H, hd, n_pages, P_max = K * 2, 16, 8, 3
    q, kp, vp, pt, ln = make_case(
        jax.random.PRNGKey(B * 7 + K + page), B, H, K, hd, n_pages, page,
        P_max, page * P_max)
    ref = paged_attention(q, kp, vp, pt, ln, use_pallas=False)
    got = paged_attention(q, kp, vp, pt, ln, use_pallas=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
