"""Tests for repro.analysis — the rule engine, golden fixtures, the
suppression syntax, and the CLI.

The golden fixtures under ``tests/fixtures/lint/<rule>/`` are the
regression contract: each rule has at least one committed true positive
(``tp_*.py``) that must keep producing a finding — including the two
historical bugs (PR 2's ``hash(gid)`` seeding, PR 5's missing tombstone
revoke-on-put) — and at least one near miss (``nm_*.py``) that must stay
silent, so rule tightening and loosening both fail loudly.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, analyze_paths

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"
SRC_REPRO = REPO / "src" / "repro"


def _rule_findings(path, rule):
    return [f for f in analyze_paths([path], select={rule})
            if f.rule == rule]


def _fixture_cases():
    cases = []
    for ruledir in sorted(FIXTURES.iterdir()):
        rule = ruledir.name.upper()
        for f in sorted(ruledir.glob("*.py")):
            cases.append((rule, f, f.name.startswith("tp_")))
    return cases


# ------------------------------------------------------------ golden fixtures
@pytest.mark.parametrize(
    "rule,path,positive", _fixture_cases(),
    ids=[f"{r}-{p.name}" for r, p, _ in _fixture_cases()])
def test_fixture(rule, path, positive):
    hits = _rule_findings(path, rule)
    if positive:
        assert hits, f"{path.name} must trigger {rule}"
        for f in hits:
            assert f.rule == rule
            assert f.path.endswith(path.name)
            assert f.line > 0 and f.message
    else:
        assert not hits, (f"{path.name} must stay clean for {rule}: "
                          f"{[f.format() for f in hits]}")


def test_every_rule_has_tp_and_nm_fixture():
    for rule in RULES:
        ruledir = FIXTURES / rule.lower()
        assert ruledir.is_dir(), f"missing fixture dir for {rule}"
        assert list(ruledir.glob("tp_*.py")), f"{rule} needs a tp_ fixture"
        assert list(ruledir.glob("nm_*.py")), f"{rule} needs an nm_ fixture"


def test_rule_catalog_shape():
    assert set(RULES) == {
        "EDK001", "EDK002", "EDK003", "EDK004",
        "EDK101", "EDK102", "EDK103", "EDK104",
        "EDK201", "EDK202", "EDK203", "EDK301"}
    for rule in RULES.values():
        assert rule.summary
        assert rule.severity in ("error", "warning")


# ------------------------------------------------- the historical bug classes
def test_pr2_hash_seed_bug_fails_lint():
    """Re-introducing PR 2's process-salted arrival seeding is caught."""
    hits = _rule_findings(FIXTURES / "edk001" / "tp_pr2_hash_seed.py",
                          "EDK001")
    assert hits and "hash()" in hits[0].message


def test_pr5_resurrection_bug_fails_lint():
    """Removing the tombstone revoke-on-put (PR 5's fix) is caught."""
    hits = _rule_findings(FIXTURES / "edk203" / "tp_pr5_resurrection.py",
                          "EDK203")
    assert hits and "revoke-on-put" in hits[0].message


# ------------------------------------------------------------- repo is clean
def test_src_repro_is_clean():
    """The gate CI enforces: the real tree has zero findings."""
    findings = analyze_paths([SRC_REPRO])
    assert findings == [], "\n".join(f.format() for f in findings)


# -------------------------------------------------------------- suppressions
def _analyze_source(tmp_path, source, select=None):
    f = tmp_path / "mod.py"
    f.write_text(textwrap.dedent(source))
    return analyze_paths([f], select=select)


def test_trailing_suppression(tmp_path):
    src = """\
    def seed(gid):
        return hash(gid)  # lint: ignore[EDK001]
    """
    assert _analyze_source(tmp_path, src, {"EDK001"}) == []


def test_standalone_suppression_covers_next_line(tmp_path):
    src = """\
    def seed(gid):
        # lint: ignore[EDK001]
        return hash(gid)
    """
    assert _analyze_source(tmp_path, src, {"EDK001"}) == []


def test_comma_list_and_bare_suppression(tmp_path):
    import random  # noqa: F401  (the fixture imports it, not us)
    src = """\
    import random
    def seed(gid):
        return hash(gid) + random.random()  # lint: ignore[EDK001, EDK003]
    def roll():
        return random.random()  # lint: ignore
    """
    assert _analyze_source(tmp_path, src) == []


def test_suppression_is_rule_specific(tmp_path):
    src = """\
    import random
    def seed(gid):
        return hash(gid) + random.random()  # lint: ignore[EDK001]
    """
    hits = _analyze_source(tmp_path, src)
    assert [f.rule for f in hits] == ["EDK003"]


def test_unparseable_file_reports_edk000(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def broken(:\n")
    hits = analyze_paths([f])
    assert [f.rule for f in hits] == ["EDK000"]
    assert "does not parse" in hits[0].message


# ----------------------------------------------------------------------- CLI
def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=REPO, env=env)


def test_cli_clean_tree_exits_zero():
    proc = _run_cli("src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_findings_exit_one_with_location():
    tp = "tests/fixtures/lint/edk001/tp_pr2_hash_seed.py"
    proc = _run_cli(tp)
    assert proc.returncode == 1
    assert "EDK001" in proc.stdout and "tp_pr2_hash_seed.py:" in proc.stdout


def test_cli_json_output():
    tp = "tests/fixtures/lint/edk203/tp_pr5_resurrection.py"
    proc = _run_cli(tp, "--json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload and payload[0]["rule"] == "EDK203"
    assert set(payload[0]) == {"rule", "severity", "path", "line", "col",
                               "message"}


def test_cli_select_filters_rules():
    tp = "tests/fixtures/lint/edk001/tp_pr2_hash_seed.py"
    proc = _run_cli(tp, "--select", "EDK002")
    assert proc.returncode == 0, proc.stdout


def test_cli_unknown_rule_is_usage_error():
    proc = _run_cli("src/repro", "--select", "EDK999")
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in RULES:
        assert rule in proc.stdout


# -------------------------------------------------------------- mypy gate
def test_mypy_gate_layers_are_clean():
    """The CI type gate (mypy.ini) over repro.core / repro.fault /
    repro.analysis; skips where mypy is not installed (the gate is
    enforced by CI, which installs requirements-dev)."""
    pytest.importorskip("mypy")
    env = dict(os.environ, MYPYPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file", "mypy.ini",
         "-p", "repro.core", "-p", "repro.fault", "-p", "repro.analysis"],
        capture_output=True, text=True, cwd=REPO, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
