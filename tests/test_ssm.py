"""SSD chunked-scan correctness vs sequential oracle + block invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import (ssd_chunked, ssd_ref, mamba2_init,
                              mamba2_apply, mlstm_init, mlstm_apply,
                              slstm_init, slstm_apply)


def rand_inputs(key, B, S, H, P, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    loga = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    dt = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, H)))
    Bm = jax.random.normal(ks[3], (B, S, N)) / np.sqrt(N)
    Cm = jax.random.normal(ks[4], (B, S, N)) / np.sqrt(N)
    return x, loga, dt, Bm, Cm


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (24, 8), (8, 8)])
def test_ssd_chunked_matches_sequential(S, chunk):
    x, loga, dt, Bm, Cm = rand_inputs(jax.random.PRNGKey(0), 2, S, 3, 8, 4)
    y_ref, h_ref = ssd_ref(x, loga, dt, Bm, Cm)
    y, h = ssd_chunked(x, loga, dt, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in two with state carry == one full pass."""
    x, loga, dt, Bm, Cm = rand_inputs(jax.random.PRNGKey(1), 1, 32, 2, 8, 4)
    y_full, h_full = ssd_chunked(x, loga, dt, Bm, Cm, chunk=8)
    y1, h1 = ssd_chunked(x[:, :16], loga[:, :16], dt[:, :16], Bm[:, :16],
                         Cm[:, :16], chunk=8)
    y2, h2 = ssd_chunked(x[:, 16:], loga[:, 16:], dt[:, 16:], Bm[:, 16:],
                         Cm[:, 16:], chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([4, 8, 16]))
def test_ssd_property_decay_bounds(B, H, S):
    """With zero decay (loga=-inf -> a=0) output reduces to per-step
    C_t.(B_t x_t dt_t) — no cross-timestep leakage."""
    key = jax.random.PRNGKey(B * 100 + H * 10 + S)
    x, _, dt, Bm, Cm = rand_inputs(key, B, S, H, 4, 4)
    loga = jnp.full((B, S, H), -50.0)
    y, _ = ssd_chunked(x, loga, dt, Bm, Cm, chunk=4)
    expect = jnp.einsum("bsd,bsd,bshp->bshp",
                        Cm, Bm, x * dt[..., None])
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_decode_matches_prefill():
    D = 32
    p = mamba2_init(jax.random.PRNGKey(0), D, expand=2, d_state=8, conv_k=4,
                    head_p=16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, D))
    d_in = 2 * D
    nh = d_in // 16
    zero = {"conv": jnp.zeros((2, 3, d_in + 16)),
            "ssm": jnp.zeros((2, nh, 8, 16))}
    y_full, _ = mamba2_apply(p, x, expand=2, d_state=8, head_p=16, chunk=4,
                             state=zero)
    # stepwise
    st_ = dict(zero)
    ys = []
    for t in range(12):
        y, st_ = mamba2_apply(p, x[:, t:t + 1], expand=2, d_state=8,
                              head_p=16, state=st_)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=1e-3, atol=1e-3)


def test_mlstm_decode_matches_parallel():
    D, H = 16, 2
    p = mlstm_init(jax.random.PRNGKey(0), D, H)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D))
    hd = 2 * D // H
    zero = {"num": jnp.zeros((2 * H, 1, hd, hd)),
            "den": jnp.zeros((2 * H, 1, hd, 1))}
    y_full, _ = mlstm_apply(p, x, H, chunk=4, state=zero)
    st_ = dict(zero)
    ys = []
    for t in range(8):
        y, st_ = mlstm_apply(p, x[:, t:t + 1], H, state=st_)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)


def test_slstm_sequential_state():
    D = 16
    p = slstm_init(jax.random.PRNGKey(0), D)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, D))
    zero = {"h": jnp.zeros((2, D)), "c": jnp.zeros((2, D)),
            "n": jnp.ones((2, D))}
    y_full, _ = slstm_apply(p, x, state=zero)
    st_ = dict(zero)
    ys = []
    for t in range(6):
        y, st_ = slstm_apply(p, x[:, t:t + 1], state=st_)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
