"""Incremental Chord maintenance: equivalence with the from-scratch
rebuild, churn edge cases, and the routing fast path."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hashring import ChordRing


def fingers_snapshot(ring: ChordRing):
    return {vh: [(e.start, e.node) for e in tab]
            for vh, tab in ring._fingers.items()}


def apply_churn(ring: ChordRing, seq, *, weights=(1.0, 1.0, 2.0, 0.5)):
    """Drive a deterministic add/remove sequence from a list of ints."""
    live, nid = [], 0
    for step in seq:
        if live and step % 3 == 0:  # remove roughly a third of the time
            victim = live.pop(step % len(live))
            ring.remove_node(victim)
        else:
            name = f"n{nid}"
            nid += 1
            ring.add_node(name, weight=weights[step % len(weights)])
            live.append(name)
    return live


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=40),
       st.integers(1, 4))
def test_incremental_fingers_equal_rebuild(seq, vnodes):
    """After any churn sequence (weighted vnodes included), incrementally
    maintained finger tables are identical to a from-scratch build."""
    ring = ChordRing(virtual_nodes=vnodes)
    apply_churn(ring, seq)
    incremental = fingers_snapshot(ring)
    ring._rebuild_fingers()
    assert incremental == fingers_snapshot(ring)


def test_add_node_never_triggers_full_rebuild():
    ring = ChordRing(virtual_nodes=4)
    for i in range(32):
        ring.add_node(f"gw{i}")
    for i in range(0, 32, 3):
        ring.remove_node(f"gw{i}")
    assert ring.finger_rebuilds == 0
    assert ring.incremental_updates == 32 + 11


def test_route_on_single_node_ring():
    ring = ChordRing()
    ring.add_node("only")
    for i in range(20):
        assert ring.route("only", f"k{i}") == ["only"]
        assert ring.locate(f"k{i}") == "only"


def test_remove_to_empty_then_readd():
    ring = ChordRing(virtual_nodes=2)
    ring.add_node("a")
    ring.add_node("b")
    ring.remove_node("a")
    ring.remove_node("b")
    assert len(ring) == 0
    assert ring._fingers == {}
    with pytest.raises(RuntimeError):
        ring.locate("k")
    ring.add_node("c")
    assert ring.locate("k") == "c"
    assert ring.route("c", "k") == ["c"]
    assert ring.finger_rebuilds == 0


def test_weighted_churn_preserves_share():
    ring = ChordRing(virtual_nodes=16)
    ring.add_node("big", weight=3.0)
    ring.add_node("small", weight=1.0)
    ring.add_node("tmp", weight=2.0)
    ring.remove_node("tmp")
    keys = [f"k{i}" for i in range(4000)]
    dist = ring.key_distribution(keys)
    assert dist["big"] > 2.0 * dist["small"]
    # tables still exact after the weighted add/remove cycle
    incremental = fingers_snapshot(ring)
    ring._rebuild_fingers()
    assert incremental == fingers_snapshot(ring)


def test_closest_preceding_uses_stored_fingers():
    """Regression for the routing fast path: a hop scans stored
    FingerEntry.node values and must not re-bisect the ring per finger
    (previously up to BITS extra ``_succ_vhash`` calls per hop)."""
    ring = ChordRing()
    for i in range(32):
        ring.add_node(f"gw{i}")
    calls = 0
    real = ring._succ_vhash

    def counting(point):
        nonlocal calls
        calls += 1
        return real(point)

    ring._succ_vhash = counting
    for i in range(40):
        path = ring.route("gw0", f"key-{i}")
        # route() itself calls _succ_vhash once per loop iteration; the
        # old _closest_preceding added up to BITS calls per hop.
        assert calls <= 2 * (len(path) + 2), (i, calls, path)
        calls = 0
    ring._succ_vhash = real


def test_routing_path_unchanged_after_churn():
    """Routes computed on a churned ring equal routes on an identically
    shaped fresh ring (same membership, fresh tables)."""
    churned = ChordRing(virtual_nodes=2)
    for i in range(24):
        churned.add_node(f"gw{i}")
    for i in range(0, 24, 4):
        churned.remove_node(f"gw{i}")

    fresh = ChordRing(virtual_nodes=2)
    for i in range(24):
        if i % 4:
            fresh.add_node(f"gw{i}")

    # membership differs in insertion order bookkeeping only; hashes agree
    assert sorted(churned._vhashes) == sorted(fresh._vhashes)
    for i in range(100):
        key = f"key-{i}"
        assert churned.route("gw1", key) == fresh.route("gw1", key)
        assert churned.locate(key) == fresh.locate(key)
