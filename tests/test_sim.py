"""Testbed-emulation tests: determinism, protocol timing sanity, and the
paper's headline claims (EXPERIMENTS.md §Repro reads from the same code)."""
import math

import pytest

from repro.sim import SimEdgeKV, ServiceParams, YCSBWorkload
from repro.sim.experiments import (fig5_6_locality, headline_claims)


def small(setting, p_global, **kw):
    sim = SimEdgeKV(setting=setting, seed=1)
    sim.run_closed_loop(threads_per_client=20, ops_per_client=400,
                        workload_kw=dict(p_global=p_global, **kw))
    return sim


def test_deterministic_replay():
    a = small("edge", 0.5)
    b = small("edge", 0.5)
    assert [r.latency for r in a.records] == [r.latency for r in b.records]


def test_seed_offset_deterministic_and_uniform():
    """Same seed_offset => identical trace; the offset shifts EVERY
    client's workload (regression: it used to apply to group 0 only) and
    never mutates the caller's workload kwargs."""
    def run(off):
        kw = dict(p_global=0.5)
        sim = SimEdgeKV(setting="edge", seed=1)
        sim.run_closed_loop(threads_per_client=10, ops_per_client=200,
                            workload_kw=kw, seed_offset=off)
        assert kw == dict(p_global=0.5)  # caller dict untouched
        return sim

    a, b, c = run(4), run(4), run(0)
    assert [r.latency for r in a.records] == [r.latency for r in b.records]
    assert [r.latency for r in a.records] != [r.latency for r in c.records]
    # uniform application: every group's op mix differs from offset 0, not
    # just g0's (each group's workload seed shifted by the same offset)
    for gid in ("g0", "g1", "g2"):
        a_kinds = [r.kind for r in a.records if r.group == gid]
        c_kinds = [r.kind for r in c.records if r.group == gid]
        assert a_kinds != c_kinds, gid


def test_edge_beats_cloud_locally():
    e = small("edge", 0.0)
    c = small("cloud", 0.0)
    assert e.mean_latency(kind="update") < c.mean_latency(kind="update")
    assert e.throughput() > c.throughput()


def test_global_slower_than_local_on_edge():
    e_loc = small("edge", 0.0)
    e_glob = small("edge", 1.0)
    assert e_glob.mean_latency() > e_loc.mean_latency()


def test_cloud_insensitive_to_locality():
    """In the cloud setting all nodes are colocated: global routing adds
    only ~0.05 ms hops, so locality barely matters (paper's premise)."""
    c_loc = small("cloud", 0.0)
    c_glob = small("cloud", 1.0)
    ratio = c_glob.mean_latency() / c_loc.mean_latency()
    assert ratio < 1.1


def test_write_latency_floor_edge():
    """An unloaded local edge write must cost at least the protocol floor:
    cli-st RTT (10ms) + quorum RTT (>=2*2ms) + commit service."""
    sim = SimEdgeKV(setting="edge", seed=3)
    sim.run_closed_loop(threads_per_client=1, ops_per_client=50,
                        workload_kw=dict(p_global=0.0))
    lat = sim.mean_latency(kind="update")
    assert lat >= (10 + 4 + 0.9) * 1e-3 * 0.99
    assert lat <= 30e-3  # and nowhere near cloud numbers


def test_dht_hops_recorded_for_global_ops():
    sim = small("edge", 1.0)
    hops = [r.remote_hops for r in sim.records]
    assert max(hops) >= 1          # some keys live on remote groups
    assert all(h <= 3 for h in hops)  # 3-gateway ring: short paths


def test_remote_fraction_matches_ring():
    """~2/3 of global keys should be owned by a remote group (3 groups)."""
    sim = small("edge", 1.0)
    remote = sum(1 for r in sim.records if r.remote_hops > 0)
    frac = remote / len(sim.records)
    assert 0.45 < frac < 0.85


# the fig-level claims run on the fast engine in the quick tier; the
# generator-oracle versions keep the slow marker (engine equivalence is
# covered op-for-op by tests/test_vectorized.py)
ENGINES = ["fast", pytest.param("oracle", marks=pytest.mark.slow)]


@pytest.mark.parametrize("engine", ENGINES)
def test_headline_claims_match_paper(engine):
    checks = headline_claims(ops_per_client=3000, engine=engine)
    failures = [c for c in checks if not c.ok]
    assert not failures, [
        f"{c.name}: paper={c.paper} ours={c.ours:.1f}" for c in failures]


@pytest.mark.parametrize("engine", ENGINES)
def test_locality_monotone_degradation(engine):
    """Fig 5 direction: more global traffic => worse write latency. (The
    paper's 50->100 flattening is a documented partial deviation — see
    EXPERIMENTS.md §Repro; with vnodes>=8 our curve flattens too.)"""
    rows = fig5_6_locality(ops_per_client=1500, engine=engine)
    edge = {r["pct_global"]: r for r in rows if r["setting"] == "edge"}
    assert edge[0]["write_latency_ms"] < edge[50]["write_latency_ms"] \
        < edge[100]["write_latency_ms"]
    cloud = {r["pct_global"]: r for r in rows if r["setting"] == "cloud"}
    for pct in (0, 50, 100):
        assert edge[pct]["write_latency_ms"] < cloud[pct]["write_latency_ms"]


@pytest.mark.parametrize("engine", ENGINES)
def test_gateway_cache_helps_at_scale(engine):
    """Beyond-paper evaluation of §7.2: the gateway location cache saves
    O(log m) routing on hot keys — material once the ring is deep and
    keys repeat."""
    def run(cache):
        sim = SimEdgeKV(setting="edge", seed=0, group_sizes=(3,) * 16,
                        gateway_cache=cache, engine=engine)
        sim.run_closed_loop(
            threads_per_client=50, ops_per_client=2500,
            workload_kw=dict(p_global=0.5, distribution="zipfian",
                             n_records=2000))
        return sim.mean_latency(kind="update", dtype="global")

    assert run(4096) < run(0) * 0.95  # >=5% better with the cache


def test_open_loop_replay_deterministic():
    """Regression: _arrivals used hash(gid), salted per process via
    PYTHONHASHSEED — open-loop runs were only deterministic within one
    interpreter. The crc32-based seed makes same-seed replay exact."""
    def run(seed):
        sim = SimEdgeKV(setting="edge", seed=seed)
        sim.run_open_loop(rate_per_client=150, duration=1.0,
                          workload_kw=dict(p_global=0.5))
        return sim

    a, b, c = run(3), run(3), run(4)
    assert [r.latency for r in a.records] == [r.latency for r in b.records]
    # the sim seed reaches the arrival streams: different seed, new trace
    assert [r.latency for r in a.records] != [r.latency for r in c.records]


def test_ycsb_workload_proportions():
    wl = YCSBWorkload(seed=0, p_global=0.3)
    ops = wl.run_ops(4000)
    reads = sum(1 for o in ops if o.kind == "read") / len(ops)
    globs = sum(1 for o in ops if o.dtype == "global") / len(ops)
    assert abs(reads - 0.5) < 0.05
    assert abs(globs - 0.3) < 0.05


def test_ycsb_zipfian_hotset():
    wl = YCSBWorkload(seed=0, distribution="zipfian")
    ops = wl.run_ops(5000)
    hot = set(wl.keys[i] for i in wl.hotset)
    frac = sum(1 for o in ops if o.key in hot) / len(ops)
    assert 0.75 < frac < 0.85  # 80% of ops to the 20% hotset


def test_ycsb_latest_skews_recent():
    wl = YCSBWorkload(seed=0, distribution="latest")
    ops = wl.run_ops(5000)
    idx = [int(o.key[4:]) for o in ops]
    newest_half = sum(1 for i in idx if i >= wl.n // 2) / len(idx)
    assert newest_half > 0.7
