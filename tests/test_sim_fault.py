"""Simulator fault injection: crash/recover under load on both engines,
fault-event segmentation on the fast paths, and the fig_failover
experiment (cross-engine agreement + fig-scale speedup)."""
import pytest

from repro.core.kvstore import GLOBAL
from repro.sim import SimEdgeKV


def _fault_sim(engine, *, groups=8, extra=2, seed=0):
    sim = SimEdgeKV(setting="edge", seed=seed, group_sizes=(3,) * groups,
                    engine=engine)
    base = tuple(sim.groups)
    victims = tuple(sim.add_group(3)[0] for _ in range(extra))
    return sim, base, victims


def _run_crash(engine, *, ops=300, threads=50, seed=0):
    sim, base, victims = _fault_sim(engine, seed=seed)
    sim.env.process(sim.fault_proc(victims=victims, t_crash=0.05))
    sim.run_closed_loop(threads_per_client=threads, ops_per_client=ops,
                        workload_kw=dict(p_global=0.5, n_records=2000),
                        client_groups=base)
    return sim


def test_sim_crash_under_load_fast():
    sim = _run_crash("fast")
    kinds = [ev[1] for ev in sim.fault_events]
    assert kinds == ["crash", "recover", "crash", "recover"]
    assert sim.groups["g8"]["retired"] and not sim.groups["g8"]["crashed"]
    assert not sim.unavailable  # every key recovered or re-written
    assert sim.ring.stabilized
    assert sim.throughput() > 0


def test_sim_crash_exactness_invariant():
    """After crash + recovery, every global key lives only at its ring
    owner (zero lost / double-owned), on both engines."""
    for engine in ("fast", "oracle"):
        sim = _run_crash(engine)
        seen = {}
        for gid, g in sim.groups.items():
            for key in g["state"].stores[GLOBAL]:
                assert key not in seen, (key, seen[key], gid)
                seen[key] = gid
                owner = sim.group_of_gateway[sim.ring.locate(key)]
                assert owner == gid, (engine, gid, key, owner)
        assert seen, engine


def test_sim_crash_cross_engine_agreement():
    """Fault runs agree across engines within the established 2%
    statistical tolerance, and the fault schedules match exactly."""
    f = _run_crash("fast", ops=800, threads=100)
    o = _run_crash("oracle", ops=800, threads=100)
    # identical schedules (kind, gid); the key census at each event may
    # differ by the ops in flight around the instant (the engines resolve
    # writes at slightly different pipeline stages — same one-op window
    # as churn)
    assert [ev[1:3] for ev in f.fault_events] == \
        [ev[1:3] for ev in o.fault_events]
    in_flight = 100 * 8  # threads_per_client x client groups
    for (_, _, _, nf), (_, _, _, no) in zip(f.fault_events,
                                            o.fault_events):
        assert abs(nf - no) <= in_flight
    for kind in (None, "update", "read"):
        mf, mo = f.mean_latency(kind), o.mean_latency(kind)
        assert abs(mf - mo) / mo < 0.02, kind
    assert abs(f.throughput() - o.throughput()) / o.throughput() < 0.02
    # lost-op accounting agrees to within the same in-flight window (the
    # engines apply writes at different pipeline stages, so single ops
    # shift around each crash instant)
    assert abs(f.lost_ops - o.lost_ops) <= in_flight // 8


def test_sim_crash_deterministic():
    a, b = _run_crash("fast", seed=3), _run_crash("fast", seed=3)
    assert [r.latency for r in a.records] == [r.latency for r in b.records]
    assert a.churn_events == b.churn_events
    assert a.lost_ops == b.lost_ops


def test_sim_open_loop_crash_both_engines():
    results = {}
    for engine in ("fast", "oracle"):
        sim, base, victims = _fault_sim(engine, groups=6, extra=1, seed=1)
        sim.env.process(sim.fault_proc(victims=victims, t_crash=0.1))
        sim.run_open_loop(rate_per_client=300, duration=1.0,
                          workload_kw=dict(p_global=0.5),
                          client_groups=base)
        assert [ev[1] for ev in sim.fault_events] == ["crash", "recover"]
        assert sim.ring.stabilized and not sim.unavailable
        results[engine] = sim
    f, o = results["fast"], results["oracle"]
    assert abs(f.mean_latency() - o.mean_latency()) / o.mean_latency() < 0.02


def test_sim_crash_client_group_refused():
    sim = SimEdgeKV(setting="edge", seed=0, group_sizes=(3, 3, 3))
    sim.run_closed_loop(threads_per_client=5, ops_per_client=20,
                        workload_kw=dict(p_global=0.0))
    with pytest.raises(ValueError):
        sim.crash_group("g0")


def test_sim_crash_last_group_refused():
    sim = SimEdgeKV(setting="edge", seed=0, group_sizes=(3,))
    with pytest.raises(RuntimeError):
        sim.crash_group("g0")


def test_sim_recover_uncrashed_refused():
    sim = SimEdgeKV(setting="edge", seed=0, group_sizes=(3, 3))
    with pytest.raises(ValueError):
        sim.recover_group("g1")


def test_sim_unavailable_keys_tracked_and_lost_reads_counted():
    """Zipfian reads against a crashed owner's hot keys register as lost
    until recovery; a re-write revalidates the key early."""
    sim = SimEdgeKV(setting="edge", seed=2, group_sizes=(3,) * 6)
    base = tuple(sim.groups)
    gid = sim.add_group(3)[0]
    # seed the victim's store with keys it owns, mid-schedule crash
    sim.env.process(sim.fault_proc(victims=(gid,), t_crash=0.2,
                                   heartbeat_period=20e-3))
    sim.run_closed_loop(threads_per_client=50, ops_per_client=400,
                        workload_kw=dict(p_global=0.8, n_records=300,
                                         distribution="zipfian"),
                        client_groups=base)
    crash_ev = [ev for ev in sim.fault_events if ev[1] == "crash"][0]
    assert crash_ev[3] > 0  # the victim owned keys at crash time
    assert sim.lost_ops > 0  # reads hit the unavailability window
    assert not sim.unavailable


def test_sim_async_recovery_cross_engine_agreement():
    """Crash recovery through staged per-key leases (async promotion):
    both engines run the same fault schedule, agree within the 2%
    tolerance, and end with every lease released and no unavailable
    keys."""
    results = {}
    for engine in ("fast", "oracle"):
        sim, base, victims = _fault_sim(engine, seed=4)
        sim.env.process(sim.fault_proc(victims=victims, t_crash=0.05,
                                       async_handoff=True, lease_batch=8))
        sim.run_closed_loop(threads_per_client=50, ops_per_client=400,
                            workload_kw=dict(p_global=0.7, n_records=500,
                                             distribution="zipfian"),
                            client_groups=base)
        assert [ev[1] for ev in sim.fault_events] == \
            ["crash", "recover", "crash", "recover"]
        assert not sim.leases and not sim.unavailable
        assert sim.ring.stabilized
        results[engine] = sim
    f, o = results["fast"], results["oracle"]
    for kind in (None, "update", "read"):
        mf, mo = f.mean_latency(kind), o.mean_latency(kind)
        assert abs(mf - mo) / mo < 0.02, kind
    assert abs(f.throughput() - o.throughput()) / o.throughput() < 0.02


def test_sim_async_recovery_read_pull_ends_unavailability_early():
    """A read that pulls its staged lease revalidates the key: with async
    promotion the same seed must not lose MORE reads than atomic
    promotion (per-key windows close no later than the bulk window)."""
    def run(async_handoff):
        sim = SimEdgeKV(setting="edge", seed=2, group_sizes=(3,) * 6,
                        engine="fast")
        base = tuple(sim.groups)
        gid = sim.add_group(3)[0]
        sim.env.process(sim.fault_proc(
            victims=(gid,), t_crash=0.2, heartbeat_period=20e-3,
            async_handoff=async_handoff, lease_batch=4,
            lease_period=0.02))
        sim.run_closed_loop(threads_per_client=50, ops_per_client=400,
                            workload_kw=dict(p_global=0.8, n_records=300,
                                             distribution="zipfian"),
                            client_groups=base)
        assert not sim.unavailable and not sim.leases
        return sim

    atomic, leased = run(False), run(True)
    crash_ev = [ev for ev in leased.fault_events if ev[1] == "crash"][0]
    assert crash_ev[3] > 0
    assert leased.handoff_stats["leased"] > 0
    # per-key windows close no later than the bulk promotion window
    # (deterministic seeds, so this is a stable comparison)
    assert leased.lost_ops <= atomic.lost_ops


@pytest.mark.parametrize("engine", [
    "fast", pytest.param("oracle", marks=pytest.mark.slow)])
def test_fig_failover_experiment(engine):
    from repro.sim.experiments import fig_failover
    rows = fig_failover(ops_per_client=400, engine=engine)
    by = {r["scenario"]: r for r in rows}
    assert by["baseline"]["crash_events"] == 0
    assert by["failover"]["crash_events"] == 2
    assert by["failover"]["keys_unavailable"] > 0
    assert by["failover"]["keys_promoted"] > 0
    assert by["failover"]["unavailability_ms"] > 0
    for r in rows:
        assert r["throughput_ops"] > 0
        assert r["p99_latency_ms"] >= r["p95_latency_ms"] > 0
        assert r["group_p99_max_ms"] >= r["p99_latency_ms"] * 0.999


@pytest.mark.slow
def test_fig_failover_fast_matches_oracle_at_fig_scale():
    """Acceptance: fig_failover on engine="fast" agrees with the oracle
    within the established <2% tolerance and runs >=5x faster at fig
    scale."""
    from repro.sim.experiments import fig_failover
    fast = {r["scenario"]: r for r in fig_failover(engine="fast")}
    oracle = {r["scenario"]: r for r in fig_failover(engine="oracle")}
    speedups = []
    for scenario in ("baseline", "failover"):
        f, o = fast[scenario], oracle[scenario]
        for m in ("write_latency_ms", "read_latency_ms",
                  "global_write_latency_ms", "p95_latency_ms",
                  "p99_latency_ms", "throughput_ops"):
            assert abs(f[m] - o[m]) / o[m] < 0.02, (scenario, m, f[m], o[m])
        assert f["unavailability_ms"] == o["unavailability_ms"]
        speedups.append(o["walltime_s"] / f["walltime_s"])
    assert max(speedups) >= 5.0, speedups
