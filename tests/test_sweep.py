"""Sweep-engine equivalence: run_sweep's batched array program must
reproduce independent ``SimEdgeKV(engine="fast")`` open-loop runs on the
same seeds, per grid point, to float-order accuracy (<= 1e-9)."""
import numpy as np
import pytest

from repro.sim import SimEdgeKV
from repro.sim.sweep import SweepPoint, SweepResult, run_sweep, sweep_grid

TOL = 1e-9


def fast_reference(p: SweepPoint, duration: float, seed: int = 0,
                   setting: str = "edge") -> SimEdgeKV:
    sim = SimEdgeKV(setting=setting, seed=seed,
                    group_sizes=(p.group_size,) * p.groups, engine="fast")
    sim.run_open_loop(rate_per_client=p.rate, duration=duration,
                      workload_kw=dict(p_global=p.p_global,
                                       distribution=p.distribution,
                                       n_records=p.n_records))
    return sim


def assert_point_matches(row: dict, sim: SimEdgeKV) -> None:
    checks = [
        ("ops", len(sim.records)),
        ("mean_latency", sim.mean_latency()),
        ("read_latency", sim.mean_latency(kind="read")),
        ("update_latency", sim.mean_latency(kind="update")),
        ("global_latency", sim.mean_latency(dtype="global")),
        ("update_global_latency",
         sim.mean_latency(kind="update", dtype="global")),
        ("throughput", sim.throughput()),
        ("p95_latency", sim.tail_latency(95)),
        ("p99_latency", sim.tail_latency(99)),
    ]
    for name, want in checks:
        got = row[name]
        if np.isnan(want):
            assert np.isnan(got), name
            continue
        assert abs(got - want) <= TOL * max(1.0, abs(want)), \
            (name, got, want)


def test_run_sweep_matches_fast_engine_per_point():
    pts = [SweepPoint(p_global=pg, rate=r, groups=g, n_records=nr,
                      distribution=dist)
           for pg, r, g, nr, dist in [
               (0.0, 120.0, 3, 10_000, "uniform"),
               (0.5, 180.0, 3, 10_000, "zipfian"),
               (0.75, 150.0, 4, 2_500, "uniform"),
               (1.0, 100.0, 5, 10_000, "latest"),
           ]]
    res = run_sweep(pts, duration=1.5, seed=0)
    assert len(res) == len(pts)
    for i, p in enumerate(pts):
        assert_point_matches(res.row(i), fast_reference(p, 1.5))


def test_run_sweep_cloud_setting_and_seed():
    p = SweepPoint(p_global=0.5, rate=150.0, groups=3)
    res = run_sweep([p], duration=1.0, setting="cloud", seed=7)
    assert_point_matches(res.row(0),
                         fast_reference(p, 1.0, seed=7, setting="cloud"))


def test_run_sweep_pallas_scan_backend():
    pts = [SweepPoint(p_global=0.5, rate=120.0, groups=3)]
    a = run_sweep(pts, duration=1.0)
    b = run_sweep(pts, duration=1.0, scan_backend="pallas")
    for k in a.columns:
        np.testing.assert_allclose(a.columns[k], b.columns[k], rtol=1e-12)


def test_run_sweep_deterministic_and_seed_sensitive():
    pts = [SweepPoint(p_global=0.5, rate=150.0)]
    a = run_sweep(pts, duration=1.0, seed=0)
    b = run_sweep(pts, duration=1.0, seed=0)
    c = run_sweep(pts, duration=1.0, seed=3)
    assert a.columns["mean_latency"][0] == b.columns["mean_latency"][0]
    assert a.columns["mean_latency"][0] != c.columns["mean_latency"][0]


def test_sweep_grid_shape_and_rows():
    grid = sweep_grid()
    assert len(grid) == 64
    assert len({(p.p_global, p.rate, p.n_records, p.groups)
                for p in grid}) == 64
    res = run_sweep(grid[:2], duration=0.5)
    rows = res.rows()
    assert len(rows) == 2
    assert {"p_global", "rate", "groups", "mean_latency", "throughput",
            "p95_latency", "p99_latency"} <= set(rows[0])


def test_run_sweep_rejects_bad_args():
    with pytest.raises(ValueError):
        run_sweep([])
    with pytest.raises(ValueError):
        run_sweep([SweepPoint()], duration=0.0)


def test_lru_hit_mask_matches_cache_replay():
    """The vectorized penalty mask must equal an OrderedDict LRU replay,
    including the eviction (Fenwick) regime."""
    from repro.core.cache import LRUCache
    from repro.sim.vectorized import lru_hit_mask

    rng = np.random.default_rng(0)
    for capacity, nkeys, n in ((8, 30, 400), (64, 50, 500),
                               (2500, 100, 300), (5, 5, 100)):
        seq = rng.integers(0, nkeys, size=n)
        cache = LRUCache(capacity)
        want = np.zeros(n, bool)
        for i, k in enumerate(seq.tolist()):
            want[i] = cache.get(k) is not None
            cache.put(k, True)
        got = lru_hit_mask(seq, capacity)
        assert np.array_equal(got, want), (capacity, nkeys)


def test_record_array_tail_latency_and_group_tails():
    sim = SimEdgeKV(setting="edge", seed=0, engine="fast")
    sim.run_closed_loop(threads_per_client=10, ops_per_client=200,
                        workload_kw=dict(p_global=0.5))
    lat = sim.records.columns()["latency"]
    assert sim.tail_latency(95) == np.percentile(lat, 95)
    assert sim.tail_latency(99) == np.percentile(lat, 99)
    assert sim.tail_latency(95) <= sim.tail_latency(99)
    assert sim.tail_latency(50) < sim.tail_latency(99)
    # selection-aware tails
    upd = lat[sim.records.columns()["kind"] == 1]
    assert sim.tail_latency(95, kind="update") == np.percentile(upd, 95)
    # per-group extension of group_stats keeps the legacy 3-tuple intact
    legacy = sim.records.group_stats()
    count, t0, t1 = legacy["g0"]
    ext = sim.records.group_stats(percentiles=(95, 99))
    assert ext["g0"][:3] == (count, t0, t1)
    g0_lat = np.asarray([r.latency for r in sim.records
                         if r.group == "g0"])
    assert ext["g0"][3] == np.percentile(g0_lat, 95)
    assert ext["g0"][4] == np.percentile(g0_lat, 99)
    # regression: a second bulk run (extend_columns) must invalidate the
    # cached tails, not serve the first run's percentiles
    p99_first = sim.tail_latency(99)
    sim.run_closed_loop(threads_per_client=10, ops_per_client=200,
                        workload_kw=dict(p_global=1.0), seed_offset=5)
    lat2 = sim.records.columns()["latency"]
    assert sim.tail_latency(99) == np.percentile(lat2, 99)
    assert sim.tail_latency(99) != p99_first
    ext2 = sim.records.group_tails((95.0, 99.0))
    g0_lat2 = lat2[sim.records.columns()["group"] == 0]
    assert ext2["g0"][1] == np.percentile(g0_lat2, 99)


@pytest.mark.slow
def test_acceptance_64_point_grid_matches_fast_engine():
    """Acceptance: a >=64-point grid evaluated as one jitted array
    program, every point matching the fast engine within 1e-9."""
    grid = sweep_grid()
    assert len(grid) >= 64
    res = run_sweep(grid, duration=1.0, seed=0)
    for i, p in enumerate(grid):
        assert_point_matches(res.row(i), fast_reference(p, 1.0))


def measured_speedup(loop_once, sweep_once, reps: int = 3):
    """Interleaved walltime comparison: warm both sides (jit compiles,
    allocator pools), then alternate loop/sweep reps so host-load drift
    hits both, and compare *medians* — a single noisy-neighbour spike
    then lands in at most one rep per side and cannot flip the ratio the
    way best-of or single-shot timing can."""
    import statistics

    sweep_once()
    sweep_once()
    loop_once()
    loops, sweeps = [], []
    for _ in range(reps):
        loops.append(loop_once())
        sweeps.append(sweep_once())
    return statistics.median(loops) / statistics.median(sweeps), \
        loops, sweeps


def strict_perf_floor() -> bool:
    """Hard walltime floors only run where the host is quiet enough to
    make them meaningful (the nightly tier exports EDGEKV_NIGHTLY=1);
    everywhere else the ratio is printed and sanity-checked, and the
    equivalence tests carry the correctness load."""
    import os
    return os.environ.get("EDGEKV_NIGHTLY", "") not in ("", "0")


@pytest.mark.slow
def test_acceptance_sweep_speedup():
    """Acceptance: >=2x wall clock over looping the numpy fast engine at
    the 64-point grid size (median of 3 interleaved reps after warmup;
    the strict floor is nightly-only, see strict_perf_floor)."""
    import time

    grid = sweep_grid()

    def sweep_once():
        t0 = time.perf_counter()
        run_sweep(grid, duration=2.0)
        return time.perf_counter() - t0

    def loop_once():
        t0 = time.perf_counter()
        for p in grid:
            sim = fast_reference(p, 2.0)
            (sim.mean_latency(), sim.mean_latency(kind="update"),
             sim.throughput(), sim.tail_latency(95), sim.tail_latency(99))
        return time.perf_counter() - t0

    import os

    ratio, loops, sweeps = measured_speedup(loop_once, sweep_once)
    print(f"sweep speedup: {ratio:.1f}x "  # lint: ignore[EDK004] -- walltime reporting
          f"(loops={loops} sweeps={sweeps})")
    if os.cpu_count() == 1 and not strict_perf_floor():
        # single-vCPU hosts timeshare XLA's compile/execute threads with
        # the numpy loop under test, so even the gross tripwire flakes;
        # the equivalence tests above still carry the correctness load
        pytest.skip(f"1-cpu host: speedup ratio {ratio:.2f} "
                    "reported, walltime floor not enforced")
    assert ratio > 0.75, (ratio, loops, sweeps)  # gross-regression tripwire
    if strict_perf_floor():
        assert ratio >= 2.0, (ratio, loops, sweeps)
