"""Quorum checkpoint: save/restore, minority-failure tolerance, majority
loss -> backup mirror, elastic reshard bound, async overlap."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import QuorumCheckpointer


def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {
        "layers": {"w": jax.random.normal(ks[0], (4, 8, 8)),
                   "b": jax.random.normal(ks[1], (4, 8))},
        "embed": jax.random.normal(ks[2], (16, 8)),
        "count": jnp.asarray(7, jnp.int32),
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=5, replication=3)
    state = tiny_state()
    ck.save(3, state)
    out = ck.restore(jax.eval_shape(lambda: state))
    assert_tree_equal(state, out)
    assert ck.latest_step() == 3


def test_restore_survives_minority_host_loss(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=5, replication=3)
    state = tiny_state(1)
    ck.save(1, state)
    ck.kill_host(0)  # one replica of some shards gone
    out = ck.restore(jax.eval_shape(lambda: state))
    assert_tree_equal(state, out)


def test_save_with_dead_host_still_commits(tmp_path):
    """A dead host is skipped, not awaited: quorum 2/3 commits — the
    EdgeKV write rule as checkpoint straggler mitigation."""
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=5, replication=3)
    ck.kill_host(2)
    state = tiny_state(2)
    manifest = ck.save(5, state)
    for info in manifest["shards"].values():
        assert len(info["acked"]) >= 2
    out = ck.restore(jax.eval_shape(lambda: state))
    assert_tree_equal(state, out)


def test_majority_loss_blocks_save(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=3, replication=3)
    ck.kill_host(0)
    ck.kill_host(1)
    with pytest.raises(RuntimeError, match="replicas"):
        ck.save(1, tiny_state())


def test_backup_mirror_restore(tmp_path):
    """Pod-level loss: restore from the §7.3-style non-voting mirror."""
    ck = QuorumCheckpointer(str(tmp_path / "pod0"), n_hosts=4,
                            replication=3,
                            mirror_root=str(tmp_path / "pod1"))
    state = tiny_state(3)
    ck.save(9, state)
    ck._mirror_thread.join()
    for h in range(4):
        ck.kill_host(h)
    out = ck.restore(jax.eval_shape(lambda: state), prefer_backup=True)
    assert_tree_equal(state, out)


def test_elastic_reshard_moves_few_shards(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=8, replication=3)
    state = {"w" + str(i): jnp.ones((4,)) * i for i in range(64)}
    ck.save(1, state)
    res = ck.reshard(9)  # +1 host
    # consistent hashing: expect ~ K*R/m keys' replica sets to change;
    # assert well below half move
    assert res["moved"] < res["total"] * 0.7
    ck2 = QuorumCheckpointer(str(tmp_path), n_hosts=9, replication=3)
    out = ck2.restore(jax.eval_shape(lambda: state))
    assert_tree_equal(state, out)


def test_async_save_overlaps(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=4, replication=3)
    state = {"w": jnp.ones((256, 256))}
    t = ck.save_async(2, state)
    t.join()
    out = ck.restore(jax.eval_shape(lambda: state))
    assert_tree_equal(state, out)


def test_checksum_detects_corruption(tmp_path):
    ck = QuorumCheckpointer(str(tmp_path), n_hosts=3, replication=3)
    state = {"w": jnp.arange(16.0)}
    m = ck.save(1, state)
    # corrupt every replica of the shard
    for host in m["shards"]["w"]["acked"]:
        p = tmp_path / host / "step1" / "w.npy"
        arr = np.load(p)
        arr[0] = 999.0
        np.save(p, arr)
    with pytest.raises(RuntimeError, match="no surviving replica"):
        ck.restore(jax.eval_shape(lambda: state))
