"""``repro.obs`` — span-level cross-engine differentials, the metrics
registry, sweep per-stage aggregates, and the trace CLI.

The span contract mirrors the latency contract one level deeper: on
closed-loop no-churn runs the oracle's inline stage boundaries and the
fast engine's column reconstruction must agree **bit-exactly** (they are
the same float additions, recorded at the same intermediate points);
under churn the per-stage means stay within the engines' 2% statistical
envelope; ``run_sweep``'s jit-computed stage aggregates match a traced
fast-engine run to <= 1e-9.
"""
from pathlib import Path

import numpy as np
import pytest

from repro.obs import (BOUNDARY_FIELDS, Counter, Gauge, Histogram,
                       MetricsRegistry, NULL_INSTRUMENT, STAGES, TraceSet,
                       format_snapshot)
from repro.obs.__main__ import main as obs_cli
from repro.sim import SimEdgeKV
from repro.sim.records import RecordArray
from repro.sim.sweep import SweepPoint, run_sweep

REPO = Path(__file__).resolve().parent.parent
SAMPLE_TRACE = REPO / "benchmarks" / "sample_trace.json"
SPAN_COLS = ("t_start", "latency") + BOUNDARY_FIELDS
TOL = 1e-9


def traced(engine, init, run, churn_kw=None, open_loop=False):
    sim = SimEdgeKV(engine=engine, trace=True, **init)
    if churn_kw:
        sim.env.process(sim.churn_proc(**churn_kw))
    if open_loop:
        sim.run_open_loop(**run)
    else:
        sim.run_closed_loop(**run)
    return sim


def bounds_matrix(sim):
    """(9, n) absolute boundaries: t_start then the eight stage ends."""
    cols = sim.records.columns()
    return np.stack([cols["t_start"]]
                    + [cols[f] for f in BOUNDARY_FIELDS])


def stage_means(sim):
    """Mean per-stage durations, one per entry of STAGES."""
    return np.diff(bounds_matrix(sim), axis=0).mean(axis=1)


# ------------------------------------------------- span invariants (per run)
def assert_span_invariants(sim):
    cols = sim.records.columns()
    b = bounds_matrix(sim)
    # boundaries are monotone: every stage has non-negative duration
    assert (np.diff(b, axis=0) >= 0).all()
    # the decomposition telescopes exactly to the recorded latency
    assert np.array_equal(cols["b_end"] - cols["t_start"], cols["latency"])


@pytest.mark.parametrize("init,run", [
    (dict(setting="edge", seed=2),
     dict(threads_per_client=15, ops_per_client=150,
          workload_kw=dict(p_global=0.5, distribution="zipfian"))),
    (dict(setting="cloud", seed=0),
     dict(threads_per_client=10, ops_per_client=100,
          workload_kw=dict(p_global=1.0))),
    (dict(setting="edge", seed=4, group_sizes=(1, 3, 5)),
     dict(threads_per_client=10, ops_per_client=120,
          workload_kw=dict(p_global=0.7))),
    (dict(setting="edge", seed=5, virtual_nodes=4, group_sizes=(3,) * 4),
     dict(threads_per_client=10, ops_per_client=120,
          workload_kw=dict(p_global=1.0), seed_offset=7)),
])
def test_closed_loop_spans_bit_exact(init, run):
    """Closed-loop no-churn: all eight boundary columns identical across
    engines, monotone, and summing exactly to the recorded latency."""
    o = traced("oracle", init, run)
    f = traced("fast", init, run)
    assert_span_invariants(o)
    assert_span_invariants(f)
    a, b = o.records.columns(), f.records.columns()
    for col in SPAN_COLS:
        assert np.array_equal(a[col], b[col]), col


def test_tracing_does_not_perturb_either_engine():
    """trace=True must be a pure observer: base columns bit-identical to
    an untraced run, on both engines."""
    init = dict(setting="edge", seed=2)
    run = dict(threads_per_client=15, ops_per_client=150,
               workload_kw=dict(p_global=0.5))
    for engine in ("oracle", "fast"):
        plain = SimEdgeKV(engine=engine, **init)
        plain.run_closed_loop(**run)
        span = traced(engine, init, run)
        a, b = plain.records.columns(), span.records.columns()
        for col in ("t_start", "latency", "kind", "dtype", "group", "hops"):
            assert np.array_equal(a[col], b[col]), (engine, col)


def test_closed_loop_churn_spans_statistical():
    """Under membership churn the engines resolve routing at different
    instants (schedule-time vs mid-flight), so the span contract relaxes
    to the same 2% envelope the latency differentials use — per stage."""
    init = dict(setting="edge", seed=0, group_sizes=(3,) * 6)
    run = dict(threads_per_client=50, ops_per_client=500,
               workload_kw=dict(p_global=0.5, n_records=2000))
    churn = dict(t_start=0.05, period=0.1, adds=2)
    o = traced("oracle", init, run, churn_kw=churn)
    f = traced("fast", init, run, churn_kw=churn)
    assert_span_invariants(o)
    assert_span_invariants(f)
    mo, mf = stage_means(o), stage_means(f)
    for s, a, b in zip(STAGES, mo, mf):
        assert abs(b - a) <= max(0.02 * abs(a), 1e-5), (s, a, b)


def test_open_loop_spans_invariant_and_statistical():
    """Open loop draws arrivals from different RNG streams per engine, so
    spans agree only statistically — but each engine's own decomposition
    still telescopes exactly."""
    init = dict(setting="edge", seed=3)
    run = dict(rate_per_client=150.0, duration=1.0,
               workload_kw=dict(p_global=0.5))
    o = traced("oracle", init, run, open_loop=True)
    f = traced("fast", init, run, open_loop=True)
    assert_span_invariants(o)
    assert_span_invariants(f)
    assert abs(len(f.records) - len(o.records)) / len(o.records) < 0.05
    mo, mf = stage_means(o), stage_means(f)
    for s, a, b in zip(STAGES, mo, mf):
        # route rides on which ops the Poisson streams emitted (~3%) and
        # queue is tiny and clustering-sensitive — loose band, abs floor
        assert abs(b - a) <= max(0.25 * abs(a), 1e-4), (s, a, b)


# ------------------------------------------------ sweep per-stage aggregates
def sweep_stage_reference(sim):
    return stage_means(sim)


def test_open_sweep_stage_aggregates_match_fast_engine():
    pts = [SweepPoint(p_global=0.5, rate=180.0, groups=3,
                      distribution="zipfian"),
           SweepPoint(p_global=1.0, rate=100.0, groups=5,
                      distribution="latest")]
    res = run_sweep(pts, duration=1.5, seed=0)
    for i, p in enumerate(pts):
        sim = SimEdgeKV(setting="edge", seed=0, engine="fast", trace=True,
                        group_sizes=(p.group_size,) * p.groups)
        sim.run_open_loop(rate_per_client=p.rate, duration=1.5,
                          workload_kw=dict(p_global=p.p_global,
                                           distribution=p.distribution,
                                           n_records=p.n_records))
        want = sweep_stage_reference(sim)
        for si, s in enumerate(STAGES):
            got = res.columns[f"stage_{s}"][i]
            assert abs(got - want[si]) <= TOL * max(1.0, abs(want[si])), \
                (s, got, want[si])


@pytest.mark.parametrize("service_kw", [None, dict(page_cache_keys=16)])
def test_closed_sweep_stage_aggregates_match_fast_engine(service_kw):
    """Both closed-loop regimes — the fully batched jit fixed point and
    the host-side eviction path — emit the same stage aggregates the
    traced fast engine reconstructs, <= 1e-9."""
    from repro.sim.cluster import ServiceParams
    svc = ServiceParams(**service_kw) if service_kw else None
    pts = [SweepPoint(p_global=0.5, groups=3, threads=8, ops=64,
                      distribution="zipfian"),
           SweepPoint(p_global=1.0, groups=5, threads=4, ops=40)]
    res = run_sweep(pts, loop="closed", seed=0, service=svc)
    for i, p in enumerate(pts):
        sim = SimEdgeKV(setting="edge", seed=0, engine="fast", trace=True,
                        service=svc,
                        group_sizes=(p.group_size,) * p.groups)
        sim.run_closed_loop(threads_per_client=p.threads,
                            ops_per_client=p.ops,
                            workload_kw=dict(p_global=p.p_global,
                                             distribution=p.distribution,
                                             n_records=p.n_records),
                            seed_offset=0)
        want = sweep_stage_reference(sim)
        for si, s in enumerate(STAGES):
            got = res.columns[f"stage_{s}"][i]
            assert abs(got - want[si]) <= TOL * max(1.0, abs(want[si])), \
                (s, got, want[si])


# ----------------------------------------------------------- fig_trace smoke
def test_fig_trace_rows_bitexact_and_shares():
    from repro.sim.experiments import fig_trace
    rows = fig_trace(ops_per_client=60, threads=6)
    assert {r["setting"] for r in rows} == {"edge", "cloud"}
    for r in rows:
        assert r["span_bitexact"] is True
        shares = sum(r[f"share_{s}"] for s in STAGES)
        assert abs(shares - 1.0) < 1e-9
        total = sum(r[f"stage_{s}_ms"] for s in STAGES)
        assert abs(total - r["mean_latency_ms"]) < 1e-6
    edge = {r["dtype"]: r for r in rows if r["setting"] == "edge"}
    # the §7 split: global ops pay routing, local ops never do
    assert edge["local"]["stage_route_ms"] == 0.0
    assert edge["global"]["stage_route_ms"] > 1.0


# ------------------------------------------------------------------- tracer
def test_trace_set_roundtrip_and_summary(tmp_path):
    sim = traced("fast", dict(setting="edge", seed=1),
                 dict(threads_per_client=6, ops_per_client=60,
                      workload_kw=dict(p_global=0.5)))
    ts = sim.trace_set(meta=dict(figure="unit"))
    path = tmp_path / "t.json"
    ts.to_json(path)
    back = TraceSet.from_json(path)
    assert back.meta["figure"] == "unit"
    assert back.metrics == ts.metrics
    for f in ("t_start", "latency") + BOUNDARY_FIELDS:
        assert np.array_equal(back.columns[f], ts.columns[f]), f
    summary = ts.stage_summary()
    assert set(summary) == set(STAGES)
    assert abs(sum(v["share"] for v in summary.values()) - 1.0) < 1e-9
    path_txt = ts.flamegraph()
    assert "response" in path_txt and "route" in path_txt
    ranked = ts.critical_path()
    assert ranked[0]["mean"] >= ranked[-1]["mean"]
    assert {r["stage"] for r in ranked} == set(STAGES)


def test_disabled_tracer_and_registry_overhead():
    """Disabled observability must leave no footprint: untraced record
    buffers carry no span columns, and a disabled registry hands out the
    one shared null instrument (no allocation, no-op mutators)."""
    sim = SimEdgeKV(setting="edge", seed=0, engine="fast")
    sim.run_closed_loop(threads_per_client=5, ops_per_client=50,
                        workload_kw=dict(p_global=0.5))
    assert not sim.records.stages
    assert set(sim.records.columns()) == {
        "t_start", "latency", "kind", "dtype", "group", "hops"}
    with pytest.raises(ValueError):
        sim.trace_set()
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x.y")
    assert c is NULL_INSTRUMENT
    assert reg.gauge("z") is NULL_INSTRUMENT
    assert reg.histogram("h") is NULL_INSTRUMENT
    c.inc(5)
    assert reg.snapshot() == {}


# ------------------------------------------------------------------ metrics
def test_metrics_registry_instruments_and_diff():
    reg = MetricsRegistry()
    reg.counter("a.reads").inc()
    reg.counter("a.reads").inc(4)
    reg.gauge("a.depth").set(7)
    h = reg.histogram("a.lat")
    for v in (1e-4, 2e-4, 1e-3):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["a.reads"] == 5
    assert snap["a.depth"] == 7
    assert snap["a.lat.count"] == 3
    assert abs(snap["a.lat.mean"] - (1.3e-3 / 3)) < 1e-12
    assert snap["a.lat.min"] == 1e-4 and snap["a.lat.max"] == 1e-3
    assert 1e-4 <= snap["a.lat.p95"] <= 1e-3
    reg.counter("a.reads").inc(2)
    diff = MetricsRegistry.diff(snap, reg.snapshot())
    assert diff["a.reads"] == 2 and diff["a.depth"] == 0
    lines = format_snapshot(reg.snapshot(), prefix="a.")
    assert any("a.reads" in ln for ln in lines)
    with pytest.raises(TypeError):
        reg.gauge("a.reads")
    assert isinstance(reg.counter("a.reads"), Counter)
    assert isinstance(reg.gauge("a.depth"), Gauge)
    assert isinstance(reg.histogram("a.lat"), Histogram)


def test_sim_metrics_snapshot_names():
    sim = SimEdgeKV(setting="edge", seed=0, engine="fast")
    sim.run_closed_loop(threads_per_client=5, ops_per_client=50,
                        workload_kw=dict(p_global=0.5))
    m = sim.metrics()
    assert m["sim.records.count"] == 150
    assert m["sim.lost_ops"] == 0
    for name in ("sim.refusals.writes", "sim.cache.page.hits",
                 "sim.latency.mean", "sim.latency.p99",
                 "sim.churn.events"):
        assert name in m, name
    assert abs(m["sim.latency.mean"] - sim.mean_latency()) < 1e-15


# ------------------------------------------- RecordArray invalidation (fix)
def test_group_stats_invalidated_by_both_mutation_paths():
    """Regression: a group_stats/group_tails snapshot taken before an
    extend_columns (or append) must not survive the mutation."""
    ra = RecordArray()
    ra.register_group("g0")
    ra.append(0.0, 1.0, 0, 0, 0, 0)
    assert ra.group_stats()["g0"] == (1, 0.0, 1.0)
    assert ra.group_tails()["g0"]
    ra.extend_columns(np.array([5.0]), np.array([2.0]),
                      np.zeros(1, np.uint8), np.zeros(1, np.uint8),
                      np.zeros(1, np.int32), np.zeros(1, np.int32))
    count, first, last = ra.group_stats()["g0"]
    assert (count, first, last) == (2, 0.0, 7.0)
    assert ra.group_stats(percentiles=(95,))["g0"][0] == 2
    ra.append(10.0, 0.5, 0, 0, 0, 0)
    assert ra.group_stats()["g0"][0] == 3
    assert ra.group_stats()["g0"][2] == 10.5


def test_stage_record_array_requires_bounds():
    ra = RecordArray(stages=True)
    ra.register_group("g0")
    with pytest.raises(ValueError):
        ra.append(0.0, 1.0, 0, 0, 0, 0)
    with pytest.raises(ValueError):
        ra.extend_columns(np.zeros(1), np.ones(1),
                          np.zeros(1, np.uint8), np.zeros(1, np.uint8),
                          np.zeros(1, np.int32), np.zeros(1, np.int32))
    ra.append(0.0, 1.0, 0, 0, 0, 0, bounds=(0.1,) * 7 + (1.0,))
    assert ra.columns()["b_end"][0] == 1.0


# ---------------------------------------------------------------- CLI smoke
def test_cli_summarize_committed_sample(capsys):
    assert SAMPLE_TRACE.is_file(), "committed sample trace missing"
    assert obs_cli(["summarize", str(SAMPLE_TRACE)]) == 0
    out = capsys.readouterr().out
    assert "route" in out and "share" in out
    assert "sim.records.count" in out


def test_cli_flamegraph_and_critical_path(capsys):
    assert obs_cli(["flamegraph", str(SAMPLE_TRACE), "--split",
                    "dtype"]) == 0
    out = capsys.readouterr().out
    assert "critical path" in out
    assert "global" in out and "local" in out


def test_cli_diff_self_is_zero(capsys):
    assert obs_cli(["diff", str(SAMPLE_TRACE), str(SAMPLE_TRACE)]) == 0
    out = capsys.readouterr().out
    assert "+0.0000" in out


def test_cli_summarize_json(capsys):
    import json
    assert obs_cli(["summarize", str(SAMPLE_TRACE), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert set(doc["stages"]["all"]) == set(STAGES)
