"""Failover end to end: unplanned crash recovery vs planned drain, plus
pod failover for checkpointed training state.

Part 1 — the EdgeKV fault-tolerance subsystem (repro.fault):
  1. A 5-group cluster with chain-deep §7.3 backups under load.
  2. PLANNED drain (`remove_group`): the comparison run — the departing
     group hands its keys off synchronously, zero unavailability.
  3. UNPLANNED crash (`crash_group`): no drain, no goodbye. The
     phi-accrual detector accrues suspicion until the dead gateway is
     declared failed, Chord stabilization repairs successor lists and
     fingers without a full rebuild, and the backup chain's mirror is
     promoted (global keys re-home with the linearizable read barrier,
     local data is adopted under the dead group's namespace). The full
     recovery timeline is printed.

Part 2 — the same §7.3 idea at the checkpoint layer:
  4. Save a checkpoint across 8 hosts with a pod-1 mirror, grow the
     fleet 8 -> 10 (consistent hashing moves ~K·R/m shards), lose the
     whole primary pod, restore from the mirror.

Run: PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import QuorumCheckpointer
from repro.core import EdgeKVCluster, GLOBAL, LOCAL
from repro.fault import FailureCoordinator

# ---------------------------------------------------------------- part 1
print("=== unplanned crash vs planned drain (repro.fault) ===")
cluster = EdgeKVCluster([3] * 5, seed=0, backup_groups=True, backup_depth=2)
keys = {f"sensor/{i}": i for i in range(150)}
for k, v in keys.items():
    cluster.put(k, v, GLOBAL, client_group="g0")
cluster.put("calib", "local-state", LOCAL, client_group="g1")
for g in cluster.groups.values():
    for _ in range(10):
        g.raft.step()  # let the learner mirrors apply

# planned drain first: the clean path, for comparison
drained = cluster.remove_group("g4")
lost = sum(1 for k, v in keys.items()
           if cluster.get(k, GLOBAL, client_group="g0").value != v)
print(f"planned drain of g4: {drained} keys handed off synchronously, "
      f"{len(keys) - lost}/{len(keys)} readable (no unavailability window)")

# unplanned crash: detector -> stabilize -> promote
coord = FailureCoordinator(cluster, heartbeat_period=0.05, threshold=8.0,
                           stabilize_period=0.1, seed=0)
coord.warmup(beats=20)
coord.crash("g1")
own_g1 = [k for k in keys if k in cluster.dead_groups["g1"][0].storage[
    cluster.dead_groups["g1"][0].node_ids[0]].stores[GLOBAL]]
print(f"g1 crashed holding {len(own_g1)} of the global keys "
      f"(+ its local data); ring stabilized: {cluster.ring.stabilized}")
coord.run_recovery()

print("\nrecovery timeline (virtual time):")
for ev in coord.timeline:
    print(f"  t={ev.t * 1e3:8.1f} ms  {ev.step:<16} {ev.detail}")
print(f"  unavailability window: "
      f"{1e3 * coord.unavailability_window():.1f} ms")

lost = sum(1 for k, v in keys.items()
           if cluster.get(k, GLOBAL, client_group="g0").value != v)
assert lost == 0, f"lost {lost} keys"
r = cluster.get("calib", LOCAL, client_group="g1")
assert r.value == "local-state"
print(f"after recovery: {len(keys)}/{len(keys)} global keys readable, "
      f"g1's local data served by {cluster.promoted_local['g1']}, "
      f"finger rebuilds: {cluster.ring.finger_rebuilds}, "
      f"repairs: {cluster.ring.stabilize_repairs} successor entries + "
      f"{cluster.ring.finger_repairs} fingers")

# ---------------------------------------------------------------- part 2
print("\n=== pod failover for checkpointed training state ===")
state = {f"layer{i}": {"w": jnp.ones((64, 64)) * i,
                       "b": jnp.zeros((64,)) + i}
         for i in range(12)}
template = jax.eval_shape(lambda: state)

with tempfile.TemporaryDirectory() as d:
    ck = QuorumCheckpointer(d + "/pod0", n_hosts=8, replication=3,
                            mirror_root=d + "/pod1-mirror")
    ck.save(100, state)
    ck._mirror_thread.join()
    print("saved step 100 across 8 hosts (+ pod-1 mirror)")

    res = ck.reshard(10)
    print(f"elastic 8->10 hosts: moved {res['moved']}/{res['total']} "
          f"replica sets (consistent hashing: only sets the new hosts "
          f"enter are touched; a naive rehash would move ~all)")
    ck10 = QuorumCheckpointer(d + "/pod0", n_hosts=10, replication=3)
    out = ck10.restore(template)
    np.testing.assert_array_equal(np.asarray(out["layer7"]["w"]),
                                  np.asarray(state["layer7"]["w"]))
    print("restore on the 10-host fleet: ok")

    for h in range(8):
        ck.kill_host(h)
    print("primary pod lost (8/8 hosts down)...")
    out = ck.restore(template, prefer_backup=True)
    np.testing.assert_array_equal(np.asarray(out["layer3"]["b"]),
                                  np.asarray(state["layer3"]["b"]))
    print("restored full state from the pod-1 mirror: ok")
