"""Elastic scaling + pod failover for checkpointed training state.

1. Save a checkpoint across 8 hosts with a pod-1 mirror (EdgeKV §7.3
   non-voting backup).
2. Grow the fleet 8 -> 10 hosts: consistent hashing moves only ~K·R/m
   shards (printed).
3. Lose the whole primary pod: restore from the mirror.

Run: PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import QuorumCheckpointer

state = {f"layer{i}": {"w": jnp.ones((64, 64)) * i,
                       "b": jnp.zeros((64,)) + i}
         for i in range(12)}
template = jax.eval_shape(lambda: state)

with tempfile.TemporaryDirectory() as d:
    ck = QuorumCheckpointer(d + "/pod0", n_hosts=8, replication=3,
                            mirror_root=d + "/pod1-mirror")
    ck.save(100, state)
    ck._mirror_thread.join()
    print("saved step 100 across 8 hosts (+ pod-1 mirror)")

    res = ck.reshard(10)
    print(f"elastic 8->10 hosts: moved {res['moved']}/{res['total']} "
          f"replica sets (consistent hashing: only sets the new hosts "
          f"enter are touched; a naive rehash would move ~all)")
    ck10 = QuorumCheckpointer(d + "/pod0", n_hosts=10, replication=3)
    out = ck10.restore(template)
    np.testing.assert_array_equal(np.asarray(out["layer7"]["w"]),
                                  np.asarray(state["layer7"]["w"]))
    print("restore on the 10-host fleet: ok")

    for h in range(8):
        ck.kill_host(h)
    print("primary pod lost (8/8 hosts down)...")
    out = ck.restore(template, prefer_backup=True)
    np.testing.assert_array_equal(np.asarray(out["layer3"]["b"]),
                                  np.asarray(state["layer3"]["b"]))
    print("restored full state from the pod-1 mirror: ok")
