"""Elastic gateway churn on the EdgeKV global layer.

1. Start a 4-group cluster, load 200 global keys.
2. Scale OUT: `add_group` joins a new group — its gateway enters the Chord
   ring with an *incremental* finger update (no from-scratch rebuild) and
   the keys whose successor changed are handed off through the new group's
   Raft log (write at dest -> linearizable read barrier -> delete at src).
3. Scale IN: `remove_group` drains it again; every key re-homes to its
   surviving successor. Zero keys lost either way.
4. The same scenario at simulator scale: 10 groups x 100 clients with live
   churn, measuring the latency cost of membership events.

Run: PYTHONPATH=src python examples/elastic_gateways.py
"""
from repro.core import EdgeKVCluster, GLOBAL
from repro.core.hashring import ChordRing
from repro.sim import SimEdgeKV

cluster = EdgeKVCluster([3, 3, 3, 3], seed=0)
keys = {f"sensor/{i}": i for i in range(200)}
for k, v in keys.items():
    cluster.put(k, v, GLOBAL, client_group="g0")

# predict the handoff with the consistent-hashing remap bound: ~K/(m+1)
# (gateway ids fully determine the ring, so a bare probe ring suffices)
probe = ChordRing()
for i in range(5):
    probe.add_node(f"gw{i}")
predicted = cluster.ring.moved_keys(list(keys), probe)

gid = cluster.add_group(3)
event, _, moved = cluster.migrations[-1]
print(f"scale-out: joined {gid}, handed off {moved} keys "
      f"(consistent hashing predicted {predicted}); "
      f"full finger rebuilds: {cluster.ring.finger_rebuilds}")

lost = sum(1 for k, v in keys.items()
           if cluster.get(k, GLOBAL, client_group="g1").value != v)
print(f"after scale-out: {len(keys) - lost}/{len(keys)} keys readable")

moved_back = cluster.remove_group(gid)
lost = sum(1 for k, v in keys.items()
           if cluster.get(k, GLOBAL, client_group="g2").value != v)
print(f"scale-in: drained {gid}, re-homed {moved_back} keys; "
      f"{len(keys) - lost}/{len(keys)} keys readable")
assert lost == 0

# -- async handoff: the same join, but WHILE clients keep writing --------
# add_group(async_handoff=True) leases the moving keys instead of
# migrating them atomically: the ring flips immediately, a write to an
# in-flight key commits at the destination (superseding the source
# copy), a read pulls its key on demand, and step_handoff drains the
# rest in the background, a few keys at a time.
gid = cluster.add_group(3, async_handoff=True)
leased = cluster.pending_handoff
hot = next(l.key for l in cluster.leases.active())
cluster.put(hot, "fresh-during-migration", GLOBAL, client_group="g0")
keys[hot] = "fresh-during-migration"
steps = 0
while cluster.pending_handoff:
    cluster.step_handoff(8)       # background driver, 8 keys per tick
    steps += 1
lost = sum(1 for k, v in keys.items()
           if cluster.get(k, GLOBAL, client_group="g1").value != v)
print(f"async scale-out: {leased} keys leased to {gid}, drained in "
      f"{steps} background steps while a client overwrote {hot!r}; "
      f"lease outcomes {dict(cluster.leases.stats)}; "
      f"{len(keys) - lost}/{len(keys)} keys readable")
assert lost == 0
cluster.remove_group(gid)

print("\nsimulated churn under load (10 groups, 1000 closed-loop clients):")
# engine="fast": the vectorized backend (see repro.sim.vectorized) — same
# timing model, ~an order of magnitude less wall clock than the generator
# oracle, which matters at this client count
sim = SimEdgeKV(setting="edge", seed=0, group_sizes=(3,) * 10, engine="fast")
sim.env.process(sim.churn_proc(t_start=0.05, period=0.1, adds=2))
sim.run_closed_loop(threads_per_client=100, ops_per_client=500,
                    workload_kw=dict(p_global=0.5, n_records=2000))
for t, kind, gid, n in sim.churn_events:
    print(f"  t={t*1e3:7.1f} ms  {kind:>6} {gid}  ({n} keys handed off)")
print(f"  mean latency {1e3 * sim.mean_latency():.1f} ms, "
      f"throughput {sim.throughput():.0f} ops/s across "
      f"{len(sim.records)} ops")
