"""End-to-end serving driver (the paper's kind: storage-backed serving).

Batched requests share a system prefix; the EdgeKV page store dedups it
as content-hashed *global* pages while each request's own tokens are
*local* pages — then a real model prefills + decodes against it.

Run: PYTHONPATH=src python examples/serve_edgekv.py
(This is a thin wrapper over ``python -m repro.launch.serve``.)
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "stablelm-3b", "--reduced",
                "--requests", "8", "--prompt-len", "24",
                "--gen-len", "8", "--shared-prefix-len", "16"]
    main()
