"""Quickstart: the EdgeKV store end to end in 60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import EdgeKVCluster, LOCAL, GLOBAL

# Three edge groups x three storage nodes, gateways on a Chord ring,
# backup groups wired per §7.3.
cluster = EdgeKVCluster([3, 3, 3], seed=42, backup_groups=True,
                        gateway_cache=128)

# --- local data: stays in the client's group (fast path, private) -------
cluster.put("sensor:42:reading", 21.5, LOCAL, client_group="g0")
r = cluster.get("sensor:42:reading", LOCAL, client_group="g0")
print(f"local read from g0: {r.value} (quorum={r.quorum_size})")
print("visible from g1's local store?",
      cluster.get("sensor:42:reading", LOCAL, client_group="g1").value)

# --- global data: consistent-hash placed, visible everywhere ------------
cluster.put("city:temperature", 18.0, GLOBAL, client_group="g0")
for g in ("g0", "g1", "g2"):
    r = cluster.get("city:temperature", GLOBAL, client_group=g)
    print(f"global read from {g}: {r.value} "
          f"(dht_path={getattr(r, 'dht_path', None)})")

# --- strong consistency: update then read-anywhere ----------------------
cluster.put("city:temperature", 18.5, GLOBAL, client_group="g2")
assert cluster.get("city:temperature", GLOBAL,
                   client_group="g1").value == 18.5
print("linearizable update visible everywhere: ok")

# --- fault tolerance: kill a minority of the owner group ----------------
owner_gw = cluster.ring.locate("city:temperature")
owner = cluster.gateways[owner_gw].group
victims = owner.crash_minority()
r = cluster.get("city:temperature", GLOBAL, client_group="g0")
print(f"after crashing {victims} in owner group {owner.id}: "
      f"read still ok -> {r.value}")

# --- §7.3: kill the majority, reads fail over to the backup group -------
owner.crash_majority()
r = cluster.get("city:temperature", GLOBAL, client_group="g0")
print(f"after majority loss: value={r.value} "
      f"from_backup={getattr(r, 'from_backup', False)}")
w = cluster.put("city:temperature", 99.0, GLOBAL, client_group="g0")
print(f"writes while owner down are rejected: ok={w.ok} "
      "(backup stays read-only so states never diverge)")

# --- testbed emulation: the same protocol under YCSB load ---------------
# Engine matrix:
#   engine="oracle"  one Python generator per client thread stepped by the
#                    event heap — the semantic ground truth.
#   engine="fast"    vectorized backend (batched numpy op schedules + a
#                    per-group max-plus commit-stage scan via
#                    repro.kernels.maxplus_scan) — bit-identical latency
#                    traces to the oracle on closed-loop runs, ~10x less
#                    wall clock. Open loop + churn runs statistically.
#   run_sweep(...)   the sweep engine: N open-loop configurations
#                    jit-compiled into ONE JAX array program — each point
#                    identical to an engine="fast" run on the same seeds.
from repro.sim import SimEdgeKV

sim = SimEdgeKV(setting="edge", seed=0, engine="fast")
sim.run_closed_loop(threads_per_client=100, ops_per_client=1000,
                    workload_kw=dict(p_global=0.5))
print(f"emulated 300 clients x YCSB-A at 50% global: "
      f"write latency {1e3 * sim.mean_latency(kind='update'):.1f} ms, "
      f"p99 {1e3 * sim.tail_latency(99):.1f} ms, "
      f"throughput {sim.throughput():.0f} ops/s "
      f"({len(sim.records)} ops, vectorized engine)")

# --- parameter sweeps: a whole what-if grid as one array program --------
# EdgeKV's evaluation is a grid of scenarios; run_sweep evaluates a
# p_global x contention x rate x groups grid in a single jitted call
# (scan_backend="pallas" routes the departure scan through the TPU
# kernel; interpret mode off-TPU).
from repro.sim import SweepPoint, run_sweep

grid = [SweepPoint(p_global=pg, rate=rate, groups=3)
        for pg in (0.0, 0.5, 1.0) for rate in (200.0, 400.0)]
res = run_sweep(grid, duration=2.0, seed=0)
print(f"swept {len(res)} configs in one jitted program "
      f"({res.walltime_s:.2f}s):")
for row in res.rows():
    print(f"  p_global={row['p_global']:.1f} rate={row['rate']:.0f}: "
          f"mean {1e3 * row['mean_latency']:.1f} ms, "
          f"p99 {1e3 * row['p99_latency']:.1f} ms, "
          f"tput {row['throughput']:.0f} ops/s")
