"""Train a small model with quorum checkpointing + simulated preemption.

Demonstrates: loss goes down; a mid-run 'preemption' (checkpoint + fresh
process state) resumes bit-exactly; a host failure during training
neither blocks the save (quorum skips it) nor the restore.

Run: PYTHONPATH=src python examples/train_small.py
"""
import tempfile

import numpy as np

from repro.configs import get_config, reduced
from repro.checkpoint import QuorumCheckpointer
from repro.train.loop import train_loop

cfg = reduced(get_config("stablelm-3b"))
print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

with tempfile.TemporaryDirectory() as d:
    ck = QuorumCheckpointer(d + "/ckpt", n_hosts=5, replication=3)

    print("phase 1: train 8 steps, checkpoint...")
    a = train_loop(cfg, steps=8, batch=4, seq_len=64, lr=3e-3, seed=7,
                   ckpt=ck, ckpt_every=100, async_ckpt=False)
    print(f"  losses: {[f'{l:.3f}' for l in a.losses]}")

    print("phase 2: a storage host dies; resume and keep training...")
    ck.kill_host(2)
    b = train_loop(cfg, steps=20, batch=4, seq_len=64, lr=3e-3, seed=7,
                   ckpt=ck, ckpt_every=100, async_ckpt=False)
    print(f"  resumed from step {b.restored_from}")
    print(f"  losses: {[f'{l:.3f}' for l in b.losses]}")

    first, last = np.mean(a.losses[:3]), np.mean(b.losses[-3:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'decreasing: ok' if last < first else 'NOT decreasing'})")
